"""Streaming serving demo: Poisson arrivals through the continuous engine.

Trains a small model briefly, converts it to LUT-int8 (the paper's deploy
form), then replays the same Poisson-arrival trace through the continuous
batching engine for both the dense and the lut-int8 operating points and
prints a throughput / latency report.

The engine-step counter doubles as the clock: requests whose arrival time
has passed are submitted before each step, so admission happens mid-decode
exactly as it would under live traffic.

A final pass demonstrates SELF-SPECULATIVE decoding (docs/speculative.md):
the dense target is served again with its own weights drafting through
the coarse LUT-int8 path — no second checkpoint, the draft tables ARE the
deploy tables — and the report adds the measured acceptance rate and
tokens per verify call. Output is token-identical to the plain dense
pass (greedy acceptance).

``--chaos`` replays a burstier trace — priorities, per-request deadlines,
bounded queues — through a 2-replica router while ``FaultSchedule.canned``
squeezes one replica's page pool, injects a decode failure and crashes
the other replica mid-decode (docs/robustness.md). The report shows what
production cares about under faults: completed / retried / shed counts,
the deadline-miss rate, and per-replica health.

Latency reporting comes straight off the engine's metrics registry
(``repro.obs``): TTFT and completion latency in BOTH clocks — engine
steps (the scheduler's arrival/finish stamps) and wall seconds (the
``perf_counter`` stamps the engine records at the same points) — plus
per-decoded-token TPOT. The demo used to keep its own step arithmetic,
which silently drifted from what the engine measured; now there is one
accounting (docs/observability.md).

``--trace out.json`` records every pass into one shared tracer and
exports a Chrome/Perfetto ``trace_event`` timeline — request lifecycle
spans, step-phase spans, and (with ``--chaos``) fault/degradation/
preemption annotations. Open it at ``ui.perfetto.dev``.

Run: PYTHONPATH=src python examples/serve_demo.py [--chaos]
     [--trace out.json]
"""
import argparse

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.obs import Obs, Tracer, validate_trace
from repro.serve import (Engine, FaultInjector, FaultSchedule, FinishReason,
                         ReplicaRouter, Request, SpecConfig)
from repro.train import TrainConfig, Trainer

SLOTS = 4
MEAN_INTERARRIVAL = 2.0        # engine steps between arrivals (Poisson)
N_REQUESTS = 12


def poisson_trace(rng: np.random.Generator):
    """(arrival_step, prompt, max_new) tuples with exponential gaps."""
    t = 0.0
    trace = []
    for i in range(N_REQUESTS):
        t += rng.exponential(MEAN_INTERARRIVAL)
        prompt = [int(x) for x in (5 * i + np.arange(3)) % 200 + 2]
        max_new = int(rng.integers(4, 16))
        trace.append((int(t), prompt, max_new))
    return trace


def serve_trace(engine: Engine, trace):
    """Drive the engine with arrivals gated on the step counter.

    Returns (requests, peak_pages_in_use)."""
    pending = list(trace)
    reqs = []
    peak_pages = 0
    while pending or engine.scheduler.has_work:
        while pending and pending[0][0] <= engine.step_count:
            arrival, prompt, max_new = pending.pop(0)
            req = Request(tokens=prompt, max_new_tokens=max_new,
                          arrival=arrival)
            reqs.append(req)
            engine.submit(req)
        # step() advances step_count even when idle, so time always moves
        # toward the next arrival
        engine.step()
        peak_pages = max(peak_pages, engine.kv.live_pages)
    return reqs, peak_pages


def report(tag: str, reqs, eng: Engine):
    """Throughput + latency report straight off the engine registry.

    One accounting: the step-clock and wall-clock families both come
    from the histograms ``repro.serve.engine._observe_request`` fills at
    finish time — the demo no longer re-derives latency from request
    fields (its old arithmetic drifted from the engine's)."""
    met = eng.obs.metrics
    toks = met.counters().get("engine.emitted_tokens", 0)
    makespan = max(r.finish_step for r in reqs) - min(r.arrival for r in reqs)
    print(f"[{tag}] {len(reqs)} requests, {toks} tokens, "
          f"makespan {makespan} steps "
          f"({toks / max(makespan, 1):.2f} tok/step)")

    def fam(label, steps_name, wall_name):
        hs = met.get_histogram(steps_name)
        hw = met.get_histogram(wall_name)
        line = f"  {label}:"
        if hs is not None and hs.count:
            line += (f" mean {hs.mean:.1f} p95 "
                     f"{hs.percentile(0.95):.1f} steps")
        if hw is not None and hw.count:
            line += (f" | mean {hw.mean * 1e3:.1f} p95 "
                     f"{hw.percentile(0.95) * 1e3:.1f} ms wall")
        print(line)

    fam("time-to-first-token", "req.ttft_steps", "req.ttft_s")
    fam("completion latency ", "req.latency_steps", "req.latency_s")
    tpot = met.get_histogram("req.tpot_s")
    if tpot is not None and tpot.count:
        print(f"  per-token (TPOT):    mean {tpot.mean * 1e3:.1f} p95 "
              f"{tpot.percentile(0.95) * 1e3:.1f} ms/token")
    for r in reqs[:4]:
        print(f"  t={r.arrival:>3} prompt={r.tokens} -> {r.out_tokens}")


def chaos_trace(rng: np.random.Generator, n_requests: int = 16):
    """A burstier arrival trace with priorities and (some) deadlines."""
    t = 0.0
    trace = []
    for i in range(n_requests):
        t += rng.exponential(1.0)
        prompt = [int(x) for x in (5 * i + np.arange(3)) % 200 + 2]
        max_new = int(rng.integers(4, 16))
        # every third request carries an SLO; the rest can wait
        deadline = int(rng.integers(10, 40)) if i % 3 == 0 else None
        trace.append((int(t), prompt, max_new, i % 2, deadline))
    return trace


def chaos_demo(model, params, tracer=None) -> None:
    """Serve the bursty trace through 2 replicas under the canned faults."""
    print("\n=== chaos: canned fault schedule over a 2-replica router ===")
    router = ReplicaRouter(
        [Engine(model, params, DENSE, batch_size=SLOTS, max_seq=96,
                page_size=16, prefill_chunk=16, max_queue=4,
                obs=Obs(tracer=tracer) if tracer is not None else None)
         for _ in range(2)])
    inj = FaultInjector(FaultSchedule.canned(replicas=2)).attach(router)
    pending = chaos_trace(np.random.default_rng(1))
    reqs = []
    while pending or router.has_work:
        while pending and pending[0][0] <= router.step_count:
            _, prompt, max_new, prio, deadline = pending.pop(0)
            req = Request(tokens=prompt, max_new_tokens=max_new,
                          priority=prio, deadline_steps=deadline)
            reqs.append(req)
            router.submit(req)      # sheds cleanly if every queue is full
        router.step()

    assert all(r.done for r in reqs), "chaos demo lost requests"
    by_reason = {}
    for r in reqs:
        by_reason[r.finish_reason.name] = \
            by_reason.get(r.finish_reason.name, 0) + 1
    slo = [r for r in reqs if r.deadline_steps is not None]
    missed = sum(r.finish_reason is FinishReason.DEADLINE for r in slo)
    print(f"[chaos] {len(reqs)} requests -> "
          + ", ".join(f"{v} {k.lower()}"
                      for k, v in sorted(by_reason.items())))
    print(f"  recovery retries: {router.retried_requests} "
          f"(requests with retries>0: "
          f"{sum(r.retries > 0 for r in reqs)})")
    print(f"  deadline-miss rate: {missed}/{len(slo)} of SLO'd requests "
          f"({100.0 * missed / max(len(slo), 1):.0f}%)")
    for i, rep in enumerate(router.stats()["replicas"]):
        print(f"  replica {i}: {rep['health']}"
              + (f" ({rep['death_reason']})" if rep["death_reason"] else "")
              + f", {rep['recovered_requests']} requests recovered")
    fired = inj.report()["by_kind"]
    print(f"  faults fired: {fired}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--chaos", action="store_true",
                    help="serve a bursty SLO'd trace through 2 replicas "
                         "under the canned fault schedule and report "
                         "completed/retried/shed counts + deadline misses")
    ap.add_argument("--trace", default="",
                    help="export the run as Chrome/Perfetto trace_event "
                         "JSON to this path (open at ui.perfetto.dev)")
    args = ap.parse_args()
    tracer = Tracer(enabled=True) if args.trace else None

    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    model = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    tc = TrainConfig(total_steps=150, lr=3e-3, warmup=10, log_every=50)
    params, _, _ = Trainer(model, ds, DENSE, tc).run(params)

    if args.chaos:
        chaos_demo(model, params, tracer)
        if tracer is not None:
            _export_trace(tracer, args.trace)
        return

    qi = QuantConfig(mode="lut_infer", v=4, c=16, lut_dtype="int8",
                     impl="ref")
    # NOTE: in production you'd run LUTBoost stages ②③ before deploying;
    # here we convert directly to show the serving path.
    from repro.core.lutboost import convert
    lut_params = convert(lambda p, b: model.forward(p, b, DENSE)[0],
                         params, ds.batch(0),
                         qi.replace(mode="lut_train"))
    lut_params = precompute_model(lut_params, qi)

    trace = poisson_trace(np.random.default_rng(0))
    streams = {}
    for tag, ps, qc, spec in [
            ("dense", params, DENSE, None),
            ("lut-int8", lut_params, qi, None),
            # self-speculative: dense target, its OWN lut-int8 tables
            # drafting (same params pytree — the drafter shares the
            # target's codebooks; docs/speculative.md)
            ("dense+lut-draft", lut_params, DENSE,
             SpecConfig(k=4, draft_qc=qi))]:
        eng = Engine(model, ps, qc, batch_size=SLOTS, max_seq=96,
                     page_size=16, prefill_chunk=16, spec_decode=spec,
                     obs=Obs(tracer=tracer) if tracer is not None else None)
        reqs, peak = serve_trace(eng, trace)
        report(tag, reqs, eng)
        streams[tag] = [r.out_tokens for r in reqs]
        print(f"  peak pages in use: {peak} "
              f"(pool {eng.kv.table.allocator.num_pages}, dense cache "
              f"would pin {SLOTS * eng.kv.table.pages_per_slot})")
        if spec is not None:
            print(f"  speculative: acceptance "
                  f"{eng.acceptance_rate:.2f}, "
                  f"{eng.tokens_per_verify:.2f} tokens/verify over "
                  f"{eng.spec_rounds} rounds")
    # greedy speculation is exact: replay the trace through a plain dense
    # engine over the SAME checkpoint and demand identical tokens
    ref_eng = Engine(model, lut_params, DENSE, batch_size=SLOTS,
                     max_seq=96, page_size=16, prefill_chunk=16)
    ref_reqs, _ = serve_trace(ref_eng, trace)
    assert streams["dense+lut-draft"] == [r.out_tokens for r in ref_reqs], \
        "speculative pass diverged from plain greedy decoding"
    print("speculative pass is token-identical to plain greedy decoding")
    if tracer is not None:
        _export_trace(tracer, args.trace)


def _export_trace(tracer, path: str) -> None:
    doc = tracer.export(path)
    problems = validate_trace(doc)
    assert not problems, f"exported trace invalid: {problems[:5]}"
    print(f"trace: {len(doc['traceEvents'])} events -> {path} "
          f"(valid; open at ui.perfetto.dev)")


if __name__ == "__main__":
    main()
