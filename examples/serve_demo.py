"""Batched serving demo: train briefly, convert to LUT-int8, serve requests
through the Engine (prefill + per-step decode with KV caches).

Run: PYTHONPATH=src python examples/serve_demo.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.serve import Engine, Request
from repro.train import TrainConfig, Trainer


def main() -> None:
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    model = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    tc = TrainConfig(total_steps=150, lr=3e-3, warmup=10, log_every=50)
    params, _, _ = Trainer(model, ds, DENSE, tc).run(params)

    qi = QuantConfig(mode="lut_infer", v=4, c=16, lut_dtype="int8",
                     impl="ref")
    # NOTE: in production you'd run LUTBoost stages ②③ before deploying;
    # here we convert directly to show the serving path.
    from repro.core.lutboost import convert
    lut_params = convert(lambda p, b: model.forward(p, b, DENSE)[0],
                         params, ds.batch(0),
                         qi.replace(mode="lut_train"))
    lut_params = precompute_model(lut_params, qi)

    for tag, ps, qc in [("dense", params, DENSE), ("lut-int8", lut_params, qi)]:
        eng = Engine(model, ps, qc, batch_size=4, max_seq=96)
        reqs = [Request(tokens=[t, t + 1, t + 2], max_new_tokens=10)
                for t in (5, 50, 111, 200)]
        eng.run(reqs)
        print(f"[{tag}]")
        for r in reqs:
            print(f"  prompt={r.tokens} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
