"""LUTBoost model conversion (paper §V): dense LM → LUT-based LM.

Stage ① k-means init from calibration activations, stage ② centroid-only
training, stage ③ joint fine-tune, then int8-LUT precompute + evaluation of
every similarity metric.

Run: PYTHONPATH=src python examples/lutboost_convert.py [--steps N]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.core.lutboost import LutBoostSchedule, convert
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--v", type=int, default=4)
    ap.add_argument("--c", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    model = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)

    # 0) a trained dense model (the conversion input)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    dense_tc = TrainConfig(total_steps=args.steps, lr=3e-3, warmup=10,
                           log_every=10**9)
    params, _, dh = Trainer(model, ds, DENSE, dense_tc).run(params)
    dense_loss = float(np.mean(dh["loss"][-10:]))
    print(f"dense model CE: {dense_loss:.4f}")

    for metric in ("l2", "l1", "chebyshev"):
        qc = QuantConfig(mode="lut_train", v=args.v, c=args.c, metric=metric,
                         recon_weight=0.05)
        # stage ①
        lut_params = convert(lambda p, b: model.forward(p, b, DENSE)[0],
                             params, ds.batch(0), qc)
        # stages ② + ③
        sched = LutBoostSchedule(stage2_steps=30, stage3_steps=70)
        tc = TrainConfig(total_steps=100, lr=1e-3, warmup=0, log_every=10**9)
        lut_params, _, hist = Trainer(model, ds, qc, tc,
                                      lutboost=sched).run(lut_params)
        # deploy at int8 tables
        qi = qc.replace(mode="lut_infer", lut_dtype="int8", impl="ref")
        pi = precompute_model(lut_params, qi)
        ev = float(np.mean([float(model.loss(pi, ds.batch(200 + i), qi)[0])
                            for i in range(4)]))
        print(f"  {metric:9s}: converted CE {ev:.4f} "
              f"(drop {ev - dense_loss:+.4f}, "
              f"equivalent bits {np.ceil(np.log2(args.c)) / args.v:.2f})")


if __name__ == "__main__":
    main()
