"""Co-design space exploration (paper §VI, Algorithm 2 + Fig 11).

Searches (v, c, metric, n_CCU, n_IMM) under area/power/accuracy constraints
and dumps the pruning heatmaps as CSV.

Run: PYTHONPATH=src python examples/dse_search.py [--area MM2] [--power MW]
"""
import argparse
import csv
import sys

from repro.dse.models import LutDlaPoint, compute_model, memory_model
from repro.dse.ppa import design_ppa
from repro.dse.search import SearchConstraints, co_design_search


def accuracy_proxy(pt: LutDlaPoint) -> float:
    """Fast stand-in for LUTBoost coarse accuracy (paper step ③): the
    empirical trends of Table V — accuracy rises with c, falls with v,
    and L1/Chebyshev cost a small penalty."""
    base = 1.0 - 0.055 * pt.v + 0.012 * min(pt.c, 48) ** 0.5 * pt.v ** 0.25
    penalty = {"l2": 0.0, "l1": 0.01, "chebyshev": 0.02}[pt.metric]
    return base - penalty


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--area", type=float, default=4.0)
    ap.add_argument("--power", type=float, default=500.0)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--k", type=int, default=768)
    ap.add_argument("--n", type=int, default=768)
    ap.add_argument("--csv", default="/tmp/dse_heatmap.csv")
    args = ap.parse_args()

    cn = SearchConstraints(m=args.m, k=args.k, n=args.n,
                           max_area_mm2=args.area, max_power_mw=args.power,
                           min_accuracy=0.9)
    best, stats = co_design_search(cn, accuracy_fn=accuracy_proxy,
                                   verbose=True)
    print("\npruning stats:", stats)
    if best is None:
        print("no feasible design under these constraints")
        sys.exit(1)
    p = best.point
    print(f"\nbest design: v={p.v} c={p.c} metric={p.metric} "
          f"n_ccu={p.n_ccu} n_imm={p.n_imm}")
    print(f"  omega={best.omega:.0f} cycles/GEMM (bound: {best.bound})")
    print(f"  area={best.area_mm2:.2f} mm2, power={best.power_mw:.0f} mW, "
          f"equiv bits={p.equivalent_bits:.2f}")

    # Fig 11-style heatmap dump over (v, c)
    with open(args.csv, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["v", "c", "metric", "ops_ratio", "mem_ratio",
                    "area_mm2", "power_mw", "accuracy"])
        for metric in ("l2", "l1", "chebyshev"):
            for v in (2, 3, 4, 6, 8, 12, 16):
                for c in (8, 16, 32, 64):
                    pt = LutDlaPoint(v=v, c=c, metric=metric)
                    ops = compute_model(args.m, args.k, args.n, pt)
                    mem = memory_model(args.m, args.k, args.n, pt)
                    ppa = design_ppa(pt)
                    w.writerow([v, c, metric,
                                f"{ops['total'] / ops['dense_ops']:.4f}",
                                f"{mem['total'] / (args.k * args.n * 8):.3f}",
                                f"{ppa.area_mm2:.3f}",
                                f"{ppa.power_mw:.1f}",
                                f"{accuracy_proxy(pt):.3f}"])
    print(f"heatmap written to {args.csv}")


if __name__ == "__main__":
    main()
