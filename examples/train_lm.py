"""End-to-end training driver: a ~100M-parameter LM on the synthetic
pipeline, with checkpoint/restart and optional LUT-mode (LUTBoost stage ③).

Default invocation runs a short smoke (25 steps). The full recipe
(~100M params, few hundred steps) is:

  PYTHONPATH=src python examples/train_lm.py --steps 300 --full

Fault tolerance: kill the process at any point and re-run — it resumes
from the latest checkpoint in --ckpt-dir.
"""
import argparse

import jax

from repro.core.lut import DENSE, QuantConfig
from repro.data import SyntheticDataset
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.train import TrainConfig, Trainer


def model_config(full: bool) -> ModelConfig:
    if full:
        # ~110M params: 12L × d768 × ff3072, vocab 32k
        return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                           d_model=768, num_heads=12, num_kv_heads=12,
                           d_ff=3072, vocab_size=32000)
    return ModelConfig(name="lm-smoke", family="dense", num_layers=4,
                       d_model=256, num_heads=8, num_kv_heads=8,
                       d_ff=1024, vocab_size=1024)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=25)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true", help="~100M config")
    ap.add_argument("--lut", action="store_true",
                    help="train in LUT mode (stage ③ joint)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_config(args.full)
    model = Model(cfg)
    qc = (QuantConfig(mode="lut_train", v=8, c=16, metric="l2")
          if args.lut else DENSE)
    params = model.init(jax.random.PRNGKey(0), qc)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name}, {n / 1e6:.1f}M params, lut={args.lut}")

    ds = SyntheticDataset(cfg, global_batch=args.batch, seq_len=args.seq)
    tc = TrainConfig(total_steps=args.steps, lr=args.lr,
                     warmup=max(args.steps // 10, 1),
                     checkpoint_every=max(args.steps // 4, 10),
                     log_every=max(args.steps // 20, 1))
    trainer = Trainer(model, ds, qc, tc, checkpoint_dir=args.ckpt_dir)
    params, _, hist = trainer.run(params)
    if hist["loss"]:
        print(f"loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f} "
              f"({len(hist['loss'])} steps, "
              f"median {sorted(hist['step_time'])[len(hist['step_time'])//2]*1e3:.0f} ms/step)")
    else:
        print("nothing to do (already trained to --steps; "
              "delete --ckpt-dir to restart)")


if __name__ == "__main__":
    main()
